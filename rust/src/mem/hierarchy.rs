//! The full CPU-side memory hierarchy (Table 2): per-core private L1/L2,
//! the shared sliced LLC with one load/store port per slice, stride
//! prefetchers at every level, and DRAM behind it.
//!
//! Both timing models use this: the baseline CPU cores access it through
//! [`CpuHierarchy::access`]; the Casper engine shares the [`SlicedLlc`] so
//! that SPUs and (reserved-way) CPU traffic see the same tag state.

use crate::config::SimConfig;
use crate::mapping::SliceMapper;
use crate::spu::{SliceState, TagBank};

use super::cache::{Cache, CacheStats};
use super::dram::DramModel;
use super::prefetch::StridePrefetcher;

/// Aggregated memory event counts — the energy model's input.
#[derive(Debug, Default, Clone, Copy)]
pub struct MemEvents {
    pub l1: CacheStats,
    pub l2: CacheStats,
    pub llc: CacheStats,
    pub dram_accesses: u64,
    pub noc_hops: u64,
}

/// Which level served a demand access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServedBy {
    L1,
    L2,
    Llc,
    Dram,
}

/// Outcome of one demand access through the hierarchy.
#[derive(Debug, Clone, Copy)]
pub struct HierAccess {
    pub latency: u64,
    pub served_by: ServedBy,
    /// The access required filling a line into L1 (miss beyond L1, or
    /// first touch of a prefetched L1 line) — consumes L1 fill bandwidth.
    pub l1_fill: bool,
}

/// The shared sliced last-level cache: a facade over the independently
/// owned per-slice states ([`SliceState`]: tag bank + single 1-access/cycle
/// 64 B port each). The epoch-parallel engine temporarily takes the banks
/// out ([`take_banks`](Self::take_banks)) so worker threads can own one
/// slice each during tag reconciliation.
#[derive(Debug, Clone)]
pub struct SlicedLlc {
    banks: Vec<SliceState>,
    way_limit: usize,
    ways: usize,
}

impl SlicedLlc {
    pub fn new(cfg: &SimConfig) -> SlicedLlc {
        SlicedLlc {
            banks: (0..cfg.llc.slices)
                .map(|_| SliceState::new(cfg.llc.slice_bytes, cfg.llc.ways, cfg.llc.line_bytes))
                .collect(),
            way_limit: cfg.llc.ways,
            ways: cfg.llc.ways,
        }
    }

    /// Borrow one slice's private state.
    #[inline]
    pub fn bank(&self, slice: usize) -> &SliceState {
        &self.banks[slice]
    }

    /// Mutably borrow one slice's private state.
    #[inline]
    pub fn bank_mut(&mut self, slice: usize) -> &mut SliceState {
        &mut self.banks[slice]
    }

    /// Move the slice states out for a parallel phase (each worker thread
    /// then owns one). Pair with [`restore_banks`](Self::restore_banks).
    pub fn take_banks(&mut self) -> Vec<SliceState> {
        std::mem::take(&mut self.banks)
    }

    /// Put the slice states back after a parallel phase, in slice order.
    pub fn restore_banks(&mut self, banks: Vec<SliceState>) {
        debug_assert!(self.banks.is_empty(), "banks restored twice");
        self.banks = banks;
    }

    /// Lend just the tag halves out, leaving the ports/counters in place.
    /// This is the pipelined engine's split: tag reconciliation (functional
    /// side) owns the [`TagBank`]s while the timing replay keeps the rest
    /// of each [`SliceState`] — legal because replay-mode requests never
    /// probe tags. Pair with
    /// [`restore_tag_banks`](Self::restore_tag_banks); until then the
    /// slices hold inert placeholders that must not be accessed.
    pub fn take_tag_banks(&mut self) -> Vec<TagBank> {
        self.banks
            .iter_mut()
            .map(|b| std::mem::replace(&mut b.tags, TagBank::placeholder()))
            .collect()
    }

    /// Put the tag halves back after a pipelined step, in slice order.
    pub fn restore_tag_banks(&mut self, tags: Vec<TagBank>) {
        debug_assert_eq!(tags.len(), self.banks.len(), "tag banks restored out of shape");
        for (b, t) in self.banks.iter_mut().zip(tags) {
            b.tags = t;
        }
    }

    /// Restrict allocations to `ways - reserved` ways (§4.4) — used while
    /// the SPUs run with concurrent CPU processes.
    pub fn set_reserved_ways(&mut self, reserved: usize) {
        assert!(reserved < self.ways);
        self.way_limit = self.ways - reserved;
    }

    pub fn way_limit(&self) -> usize {
        self.way_limit
    }

    /// Claim the slice port at `now`: returns the cycle the access starts.
    #[inline]
    pub fn claim_port(&mut self, slice: usize, now: u64) -> u64 {
        self.banks[slice].port.claim(now)
    }

    /// Total cycles requests waited on slice ports (diagnostics).
    pub fn port_wait_cycles(&self) -> u64 {
        self.banks.iter().map(|b| b.port.wait_cycles).sum()
    }

    /// Tag access on a slice (no port accounting — callers that model
    /// bandwidth call [`claim_port`](Self::claim_port) themselves).
    /// Routed through [`SliceState::tag_access`] so temporal-block
    /// wavefront residency applies identically in both engines.
    #[inline]
    pub fn access(&mut self, slice: usize, addr: u64, write: bool) -> super::cache::AccessOutcome {
        let way_limit = self.way_limit;
        self.banks[slice].tag_access(addr, write, way_limit)
    }

    pub fn probe(&self, slice: usize, addr: u64) -> bool {
        self.banks[slice].tags.cache.probe(addr)
    }

    /// Second tag match of a merged unaligned access (§4.1) — state
    /// updates and real misses, but no double-counted hit.
    pub fn access_second_tag(&mut self, slice: usize, addr: u64) -> super::cache::AccessOutcome {
        let way_limit = self.way_limit;
        self.banks[slice].tag_access_second(addr, way_limit)
    }

    /// Raise/clear the temporal-block residency flag on every slice (see
    /// [`TagBank::wavefront_resident`]). Called by the coordinator at
    /// step boundaries; the flag travels with the banks through
    /// [`take_banks`](Self::take_banks) /
    /// [`take_tag_banks`](Self::take_tag_banks), so every engine sees the
    /// same state.
    pub fn set_wavefront_resident(&mut self, resident: bool) {
        for b in &mut self.banks {
            b.tags.wavefront_resident = resident;
        }
    }

    /// Tag probes served by wavefront residency, per slice.
    pub fn avoided_fills(&self) -> Vec<u64> {
        self.banks.iter().map(|b| b.tags.avoided_fills).collect()
    }

    pub fn prefetch_fill(&mut self, slice: usize, addr: u64) -> Option<u64> {
        self.banks[slice].tags.cache.prefetch_fill(addr, self.way_limit)
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for b in &self.banks {
            s.add(&b.tags.cache.stats);
        }
        s
    }

    pub fn reset(&mut self) {
        for b in &mut self.banks {
            b.reset();
        }
    }

    /// Keep tags, clear counters (post-warm-up).
    pub fn reset_stats(&mut self) {
        for b in &mut self.banks {
            b.tags.cache.reset_stats();
        }
    }
}

/// Per-core private caches plus their prefetchers.
#[derive(Debug, Clone)]
struct CoreCaches {
    l1: Cache,
    l2: Cache,
    l1_pf: StridePrefetcher,
    l2_pf: StridePrefetcher,
}

/// The complete baseline-CPU memory system.
pub struct CpuHierarchy {
    cfg: SimConfig,
    cores: Vec<CoreCaches>,
    pub llc: SlicedLlc,
    pub llc_pf: StridePrefetcher,
    pub dram: DramModel,
    pub mapper: SliceMapper,
}

impl CpuHierarchy {
    pub fn new(cfg: &SimConfig, mapper: SliceMapper) -> CpuHierarchy {
        let cores = (0..cfg.cpu.cores)
            .map(|_| CoreCaches {
                l1: Cache::from_config(&cfg.l1),
                l2: Cache::from_config(&cfg.l2),
                l1_pf: StridePrefetcher::new(&cfg.prefetch),
                l2_pf: StridePrefetcher::new(&cfg.prefetch),
            })
            .collect();
        CpuHierarchy {
            cores,
            llc: SlicedLlc::new(cfg),
            llc_pf: StridePrefetcher::new(&cfg.prefetch),
            dram: DramModel::new(&cfg.dram, cfg.llc.line_bytes),
            mapper,
            cfg: cfg.clone(),
        }
    }

    /// One demand access from `core` at byte address `addr`. `stream_key`
    /// identifies the logical access stream for the prefetchers (the trace
    /// generator passes the array/row-group id — the PC analogue).
    pub fn access(
        &mut self,
        core: usize,
        addr: u64,
        write: bool,
        stream_key: u64,
        now: u64,
    ) -> HierAccess {
        let line_bytes = self.cfg.l1.line_bytes as u64;
        let line_addr = addr & !(line_bytes - 1);
        let key = ((core as u64) << 48) ^ stream_key;

        // --- L1 ---
        let cc = &mut self.cores[core];
        let l1_out = cc.l1.access(line_addr, write);
        // Prefetcher observes the demand stream at every level.
        let l1_prefs = cc.l1_pf.observe(key, line_addr / line_bytes);
        if l1_out.hit {
            for p in l1_prefs.iter() {
                self.prefetch_into_l1(core, p * line_bytes, now);
            }
            return HierAccess {
                latency: self.cfg.l1.latency,
                served_by: ServedBy::L1,
                l1_fill: l1_out.prefetch_hit,
            };
        }

        // --- L2 ---
        let cc = &mut self.cores[core];
        let l2_out = cc.l2.access(line_addr, false);
        if let Some(wb) = l1_out.writeback {
            // L1 victim writes back into L2.
            cc.l2.access(wb * line_bytes, true);
        }
        let l2_prefs = cc.l2_pf.observe(key, line_addr / line_bytes);
        if l2_out.hit {
            for p in l1_prefs.iter() {
                self.prefetch_into_l1(core, p * line_bytes, now);
            }
            for p in l2_prefs.iter() {
                self.prefetch_into_l2(core, p * line_bytes, now);
            }
            return HierAccess {
                latency: self.cfg.l2.latency,
                served_by: ServedBy::L2,
                l1_fill: true,
            };
        }

        // --- LLC ---
        let slice = self.mapper.slice_of(line_addr);
        let port_start = self.llc.claim_port(slice, now);
        let port_wait = port_start - now;
        let llc_out = self.llc.access(slice, line_addr, false);
        if let Some(wb) = l2_out.writeback {
            let wb_addr = wb * line_bytes;
            let wb_slice = self.mapper.slice_of(wb_addr);
            self.llc.access(wb_slice, wb_addr, true);
        }
        let llc_prefs = self.llc_pf.observe(key, line_addr / line_bytes);
        let mut latency = self.cfg.llc.core_latency + port_wait;
        let served_by;
        if llc_out.hit {
            served_by = ServedBy::Llc;
        } else {
            // --- DRAM ---
            let done = self.dram.access(line_addr, false, now + latency);
            if let Some(wb) = llc_out.writeback {
                self.dram.access(wb * line_bytes, true, now + latency);
            }
            latency = done - now;
            served_by = ServedBy::Dram;
        }
        for p in l1_prefs.iter() {
            self.prefetch_into_l1(core, p * line_bytes, now);
        }
        for p in l2_prefs.iter() {
            self.prefetch_into_l2(core, p * line_bytes, now);
        }
        for p in llc_prefs.iter() {
            self.prefetch_into_llc(p * line_bytes, now);
        }
        HierAccess { latency, served_by, l1_fill: true }
    }

    /// Prefetch a line into L1 (installs through the hierarchy, charging
    /// every level the data actually moves through: an L1 prefetch fill
    /// reads L2, an L2 fill reads the LLC, an LLC fill reads DRAM).
    fn prefetch_into_l1(&mut self, core: usize, addr: u64, now: u64) {
        let cc = &mut self.cores[core];
        if cc.l1.probe(addr) {
            return;
        }
        self.prefetch_into_l2(core, addr, now);
        let cc = &mut self.cores[core];
        // The pull from L2 is a real L2 read (now guaranteed resident).
        cc.l2.access(addr, false);
        cc.l1.prefetch_fill(addr, self.cfg.l1.ways);
    }

    fn prefetch_into_l2(&mut self, core: usize, addr: u64, now: u64) {
        let cc = &mut self.cores[core];
        if cc.l2.probe(addr) {
            return;
        }
        self.prefetch_into_llc(addr, now);
        // The pull from the LLC is a real slice read: it costs the slice
        // port (bandwidth) and LLC access energy.
        let slice = self.mapper.slice_of(addr);
        self.llc.claim_port(slice, now);
        self.llc.access(slice, addr, false);
        let cc = &mut self.cores[core];
        cc.l2.prefetch_fill(addr, self.cfg.l2.ways);
    }

    fn prefetch_into_llc(&mut self, addr: u64, now: u64) {
        let slice = self.mapper.slice_of(addr);
        if self.llc.probe(slice, addr) {
            return;
        }
        // A prefetch fill consumes the slice port and a DRAM transfer —
        // this bandwidth + pollution cost is what produces the paper's
        // Blur-2D DRAM-size anomaly (§8.1).
        self.llc.claim_port(slice, now);
        if let Some(wb) = self.llc.prefetch_fill(slice, addr) {
            self.dram.access(wb * self.cfg.llc.line_bytes as u64, true, now);
        }
        self.dram.access(addr, false, now);
    }

    /// End a warm-up phase: clear every counter and scheduler clock while
    /// keeping all tag state.
    pub fn reset_stats(&mut self) {
        for cc in &mut self.cores {
            cc.l1.reset_stats();
            cc.l2.reset_stats();
        }
        self.llc.reset_stats();
        for s in 0..self.cfg.llc.slices {
            self.llc.bank_mut(s).port.reset();
        }
        self.dram.reset();
    }

    /// Event counts for the energy model.
    pub fn events(&self) -> MemEvents {
        let mut ev = MemEvents::default();
        for cc in &self.cores {
            ev.l1.add(&cc.l1.stats);
            ev.l2.add(&cc.l2.stats);
        }
        ev.llc = self.llc.stats();
        ev.dram_accesses = self.dram.accesses;
        ev
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingPolicy, SimConfig};

    fn hier() -> CpuHierarchy {
        let cfg = SimConfig::default();
        let mapper = SliceMapper::new(&cfg.llc, MappingPolicy::Baseline);
        CpuHierarchy::new(&cfg, mapper)
    }

    #[test]
    fn first_access_goes_to_dram_then_l1() {
        let mut h = hier();
        let a = h.access(0, 0x10000, false, 1, 0);
        assert_eq!(a.served_by, ServedBy::Dram);
        assert!(a.latency > 200);
        let b = h.access(0, 0x10000, false, 1, 1000);
        assert_eq!(b.served_by, ServedBy::L1);
        assert_eq!(b.latency, 4);
    }

    #[test]
    fn l2_hit_after_l1_eviction() {
        let mut h = hier();
        h.access(0, 0, false, 12345, 0);
        // Conflict in L1 set 0 (64 sets → stride 4 KiB) while spreading
        // over L2's 512 sets; distinct stream keys defeat the prefetcher.
        let stride = 4096u64;
        for i in 1..=12u64 {
            h.access(0, i * stride, false, i * 977, 0);
        }
        // Line 0 got evicted from the 8-way L1 but survives in L2.
        let a = h.access(0, 0, false, 999, 0);
        assert_eq!(a.served_by, ServedBy::L2);
        assert_eq!(a.latency, 12);
    }

    #[test]
    fn llc_hit_latency_includes_port_wait() {
        let cfg = SimConfig::default();
        let mapper = SliceMapper::new(&cfg.llc, MappingPolicy::Baseline);
        let mut h = CpuHierarchy::new(&cfg, mapper);
        // Warm a line into LLC via core 0, then evict from core 1's L1/L2
        // is unnecessary — access from a different core misses privately
        // and hits in the shared LLC.
        h.access(0, 0x40000, false, 1, 0);
        let a = h.access(1, 0x40000, false, 2, 10_000);
        assert_eq!(a.served_by, ServedBy::Llc);
        assert!(a.latency >= cfg.llc.core_latency);
    }

    #[test]
    fn writebacks_propagate() {
        let mut h = hier();
        // Dirty a line in L1, then force it out with same-set conflicts.
        h.access(0, 0, true, 1, 0);
        let stride = 32 * 1024u64;
        for i in 1..=8u64 {
            h.access(0, i * stride, false, i * 977 + 5, 0);
        }
        // Victim went to L2 as a write (write_hits or write_misses > 0).
        let ev = h.events();
        assert!(ev.l2.write_hits + ev.l2.write_misses > 0, "L1 writeback reached L2");
    }

    #[test]
    fn streaming_triggers_prefetch_hits() {
        let mut h = hier();
        // Stream 200 consecutive lines with one stream key.
        for i in 0..200u64 {
            h.access(0, i * 64, false, 42, i * 10);
        }
        let ev = h.events();
        assert!(
            ev.l1.prefetch_hits + ev.l2.prefetch_hits + ev.llc.prefetch_hits > 50,
            "prefetchers should cover a unit-stride stream: {ev:?}"
        );
    }

    #[test]
    fn events_count_dram() {
        let mut h = hier();
        h.access(0, 0, false, 1, 0);
        assert!(h.events().dram_accesses >= 1);
    }
}
