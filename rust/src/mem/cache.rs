//! Set-associative cache model: tags, LRU replacement, write-back +
//! write-allocate, optional way restriction (Casper reserves LLC ways for
//! concurrent CPU processes, §4.4).

/// Per-cache event counters (consumed by the energy model).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub read_hits: u64,
    pub read_misses: u64,
    pub write_hits: u64,
    pub write_misses: u64,
    pub evictions: u64,
    pub writebacks: u64,
    /// Fills injected by a prefetcher (tracked separately: they pollute).
    pub prefetch_fills: u64,
    /// Demand hits on prefetched lines (prefetch usefulness).
    pub prefetch_hits: u64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.read_hits + self.write_hits
    }
    pub fn misses(&self) -> u64 {
        self.read_misses + self.write_misses
    }
    pub fn accesses(&self) -> u64 {
        self.hits() + self.misses()
    }
    pub fn hit_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.hits() as f64 / self.accesses() as f64
        }
    }
    pub fn add(&mut self, o: &CacheStats) {
        self.read_hits += o.read_hits;
        self.read_misses += o.read_misses;
        self.write_hits += o.write_hits;
        self.write_misses += o.write_misses;
        self.evictions += o.evictions;
        self.writebacks += o.writebacks;
        self.prefetch_fills += o.prefetch_fills;
        self.prefetch_hits += o.prefetch_hits;
    }
}

/// Result of one access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccessOutcome {
    pub hit: bool,
    /// Dirty line evicted by the fill (its line address), if any.
    pub writeback: Option<u64>,
    /// The hit consumed a line a prefetcher installed (first demand touch
    /// of a prefetched line — it still cost a fill into this level).
    pub prefetch_hit: bool,
    /// The access was served by temporal-block wavefront residency
    /// (see `TagBank::wavefront_resident`): no tag probe, no possible
    /// line fill. Always a hit; the tracer attributes these separately so
    /// avoided DRAM fills stay visible in the cycle-domain trace.
    pub avoided: bool,
}

/// Per-way metadata flag bits (see [`Cache::flags`]).
const FLAG_DIRTY: u8 = 1 << 0;
/// Filled by prefetch and not yet demanded.
const FLAG_PREFETCHED: u8 = 1 << 1;

/// A tag-only set-associative cache with LRU replacement.
///
/// Storage is struct-of-arrays: the hit scan — the hottest loop in the
/// whole simulator (`cache_access_1M` in `benches/micro_hotpath.rs`) —
/// touches only the dense `tags` array (8 B/way instead of a padded
/// 24 B/way record), so a 16-way set fits in two cache lines and the
/// compare loop vectorizes. Stamps and flag bytes are read only on the
/// way that hits or is evicted.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    ways: usize,
    line_shift: u32,
    /// `line + 1` per way; `0` = invalid. (Line addresses are physical
    /// addresses >> line_shift, far below `u64::MAX`, so +1 never wraps.)
    tags: Vec<u64>,
    /// LRU stamp per way (monotonic counter value at last touch).
    stamps: Vec<u64>,
    /// `FLAG_DIRTY` / `FLAG_PREFETCHED` bits per way.
    flags: Vec<u8>,
    clock: u64,
    pub stats: CacheStats,
}

impl Cache {
    /// `size_bytes` must be `sets * ways * line_bytes` with power-of-two
    /// sets and line size.
    pub fn new(size_bytes: usize, ways: usize, line_bytes: usize) -> Cache {
        assert!(line_bytes.is_power_of_two());
        assert!(size_bytes % (ways * line_bytes) == 0, "geometry mismatch");
        let sets = size_bytes / (ways * line_bytes);
        assert!(sets.is_power_of_two(), "set count must be a power of two");
        Cache {
            sets,
            ways,
            line_shift: line_bytes.trailing_zeros(),
            tags: vec![0; sets * ways],
            stamps: vec![0; sets * ways],
            flags: vec![0; sets * ways],
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn from_config(cfg: &crate::config::CacheConfig) -> Cache {
        Cache::new(cfg.size_bytes, cfg.ways, cfg.line_bytes)
    }

    #[inline]
    pub fn line_bytes(&self) -> usize {
        1 << self.line_shift
    }

    #[inline]
    pub fn line_of(&self, addr: u64) -> u64 {
        addr >> self.line_shift
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        (line as usize) & (self.sets - 1)
    }

    /// Demand access with allocate-on-miss over all ways.
    pub fn access(&mut self, addr: u64, write: bool) -> AccessOutcome {
        self.access_ways(addr, write, self.ways)
    }

    /// Demand access restricted to the first `way_limit` ways (Casper's
    /// LLC way reservation: stencil data may not evict the reserved ways).
    pub fn access_ways(&mut self, addr: u64, write: bool, way_limit: usize) -> AccessOutcome {
        debug_assert!(way_limit > 0 && way_limit <= self.ways);
        let line = self.line_of(addr);
        let set = self.set_of(line);
        self.clock += 1;
        let ways = self.ways;
        let base = set * ways;
        let key = line + 1;

        // Single pass: hit check across ALL ways (a line resident in a
        // reserved way still hits; the restriction is only on allocation)
        // while simultaneously tracking the in-window LRU victim — the
        // miss path then needs no second scan (§Perf: this function is
        // ~30% of simulator time).
        let mut hit_way = usize::MAX;
        let mut victim = 0usize;
        let mut victim_stamp = u64::MAX;
        {
            let tags = &self.tags[base..base + ways];
            let stamps = &self.stamps[base..base + ways];
            for w in 0..ways {
                let t = tags[w];
                if t == key {
                    hit_way = w;
                    break;
                }
                if w < way_limit {
                    let stamp = if t == 0 { 0 } else { stamps[w] };
                    if stamp < victim_stamp {
                        victim_stamp = stamp;
                        victim = w;
                    }
                }
            }
        }

        if hit_way != usize::MAX {
            let idx = base + hit_way;
            self.stamps[idx] = self.clock;
            let fl = self.flags[idx];
            let prefetch_hit = fl & FLAG_PREFETCHED != 0;
            let mut fl = fl & !FLAG_PREFETCHED;
            if prefetch_hit {
                self.stats.prefetch_hits += 1;
            }
            if write {
                fl |= FLAG_DIRTY;
                self.stats.write_hits += 1;
            } else {
                self.stats.read_hits += 1;
            }
            self.flags[idx] = fl;
            return AccessOutcome { hit: true, writeback: None, prefetch_hit, avoided: false };
        }

        // Miss: allocate (write-allocate policy) in the LRU way within the
        // allowed window.
        if write {
            self.stats.write_misses += 1;
        } else {
            self.stats.read_misses += 1;
        }
        let writeback = self.fill_way(base + victim, line, write, false);
        AccessOutcome { hit: false, writeback, prefetch_hit: false, avoided: false }
    }

    /// State-updating access that does NOT count a hit — used for the
    /// second line of a §4.1 merged unaligned access: the dual tag port
    /// matches both lines under ONE data-array access, so energy/stats
    /// see a single access, but a miss on either line is still a real
    /// miss (counted, fill, possible writeback).
    pub fn access_second_tag(&mut self, addr: u64, way_limit: usize) -> AccessOutcome {
        debug_assert!(way_limit > 0 && way_limit <= self.ways);
        let line = self.line_of(addr);
        let base = self.set_of(line) * self.ways;
        let key = line + 1;
        self.clock += 1;
        // Same single-pass hit + in-window LRU-victim scan as
        // [`access_ways`] — this sits on the merged-unaligned hot path, so
        // the old two-scan (find, then LRU) version cost a second pass
        // over the set on every miss. Victim choice is identical: invalid
        // ways scan as stamp 0, which no valid way can carry (the clock is
        // pre-incremented before every fill), so the first invalid way —
        // else the oldest stamp — wins, exactly as `lru_way` chose.
        let ways = self.ways;
        let mut hit_way = usize::MAX;
        let mut victim = 0usize;
        let mut victim_stamp = u64::MAX;
        {
            let tags = &self.tags[base..base + ways];
            let stamps = &self.stamps[base..base + ways];
            for w in 0..ways {
                let t = tags[w];
                if t == key {
                    hit_way = w;
                    break;
                }
                if w < way_limit {
                    let stamp = if t == 0 { 0 } else { stamps[w] };
                    if stamp < victim_stamp {
                        victim_stamp = stamp;
                        victim = w;
                    }
                }
            }
        }
        if hit_way != usize::MAX {
            // Resident: touch LRU only (no hit counted — the merged
            // access's first line carried the access).
            let idx = base + hit_way;
            self.stamps[idx] = self.clock;
            let prefetch_hit = self.flags[idx] & FLAG_PREFETCHED != 0;
            self.flags[idx] &= !FLAG_PREFETCHED;
            return AccessOutcome { hit: true, writeback: None, prefetch_hit, avoided: false };
        }
        self.stats.read_misses += 1;
        let writeback = self.fill_way(base + victim, line, false, false);
        AccessOutcome { hit: false, writeback, prefetch_hit: false, avoided: false }
    }

    /// Fill a line without a demand access (prefetch). Never counted as a
    /// hit/miss; may evict. Returns the writeback, if any. No-op if the
    /// line is already resident.
    pub fn prefetch_fill(&mut self, addr: u64, way_limit: usize) -> Option<u64> {
        let line = self.line_of(addr);
        let set = self.set_of(line);
        let base = set * self.ways;
        if self.find_way(base, line + 1).is_some() {
            return None;
        }
        self.clock += 1;
        self.stats.prefetch_fills += 1;
        let victim = self.lru_way(base, way_limit);
        self.fill_way(base + victim, line, false, true)
    }

    /// Probe without state change: is the line resident?
    pub fn probe(&self, addr: u64) -> bool {
        let line = self.line_of(addr);
        let base = self.set_of(line) * self.ways;
        self.find_way(base, line + 1).is_some()
    }

    /// Invalidate a line (coherence). Returns true if it was dirty.
    pub fn invalidate(&mut self, addr: u64) -> bool {
        let line = self.line_of(addr);
        let base = self.set_of(line) * self.ways;
        if let Some(w) = self.find_way(base, line + 1) {
            let idx = base + w;
            self.tags[idx] = 0;
            let dirty = self.flags[idx] & FLAG_DIRTY != 0;
            self.flags[idx] = 0;
            return dirty;
        }
        false
    }

    /// Fraction of valid lines (occupancy), for reports.
    pub fn occupancy(&self) -> f64 {
        let valid = self.tags.iter().filter(|&&t| t != 0).count();
        valid as f64 / self.tags.len() as f64
    }

    /// Reset tags and stats (new run).
    pub fn reset(&mut self) {
        self.tags.fill(0);
        self.stamps.fill(0);
        self.flags.fill(0);
        self.stats = CacheStats::default();
        self.clock = 0;
    }

    /// Reset statistics only, keeping the tag state (end of a warm-up
    /// phase: subsequent measurement sees a warm cache with clean
    /// counters).
    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Index (within the set) of the way holding `key` (= line + 1), if
    /// resident. Pure tag scan — the common helper for the cold paths.
    #[inline]
    fn find_way(&self, base: usize, key: u64) -> Option<usize> {
        self.tags[base..base + self.ways].iter().position(|&t| t == key)
    }

    fn lru_way(&self, base: usize, way_limit: usize) -> usize {
        // Prefer an invalid way inside the window; else the LRU stamp.
        let mut victim = 0usize;
        let mut best = u64::MAX;
        for w in 0..way_limit {
            if self.tags[base + w] == 0 {
                return w;
            }
            let stamp = self.stamps[base + w];
            if stamp < best {
                best = stamp;
                victim = w;
            }
        }
        victim
    }

    fn fill_way(&mut self, idx: usize, line: u64, dirty: bool, prefetched: bool) -> Option<u64> {
        let old = self.tags[idx];
        let mut writeback = None;
        if old != 0 {
            self.stats.evictions += 1;
            if self.flags[idx] & FLAG_DIRTY != 0 {
                self.stats.writebacks += 1;
                writeback = Some(old - 1);
            }
        }
        self.tags[idx] = line + 1;
        self.stamps[idx] = self.clock;
        self.flags[idx] = (dirty as u8) * FLAG_DIRTY | (prefetched as u8) * FLAG_PREFETCHED;
        writeback
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;
    use crate::util::SplitMix64;

    fn tiny() -> Cache {
        // 2 sets × 2 ways × 64 B = 256 B.
        Cache::new(256, 2, 64)
    }

    #[test]
    fn miss_then_hit() {
        let mut c = tiny();
        assert!(!c.access(0x1000, false).hit);
        assert!(c.access(0x1000, false).hit);
        assert!(c.access(0x1038, false).hit, "same line");
        assert_eq!(c.stats.read_hits, 2);
        assert_eq!(c.stats.read_misses, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = tiny();
        // Set 0 lines: line numbers even (2 sets, line = addr>>6, set = line&1).
        c.access(0x000, false); // line 0 set 0
        c.access(0x100, false); // line 4 set 0
        c.access(0x000, false); // touch line 0 → line 4 is LRU
        c.access(0x200, false); // line 8 set 0 → evicts line 4
        assert!(c.probe(0x000));
        assert!(!c.probe(0x100));
        assert!(c.probe(0x200));
    }

    #[test]
    fn writeback_on_dirty_eviction() {
        let mut c = tiny();
        c.access(0x000, true); // dirty line 0
        c.access(0x100, false);
        let out = c.access(0x200, false); // evicts LRU = line 0 (dirty)
        assert_eq!(out.writeback, Some(0));
        assert_eq!(c.stats.writebacks, 1);
    }

    #[test]
    fn way_restriction_protects_reserved_way() {
        // 1 set × 4 ways.
        let mut c = Cache::new(256, 4, 64);
        // Fill all 4 ways (unrestricted).
        for i in 0..4u64 {
            c.access(i * 64, false);
        }
        // Touch way occupants so stamps are ordered 0..3; then restricted
        // allocation (3 ways) must never evict whatever sits in way 3.
        let before = c.probe(3 * 64);
        assert!(before);
        for i in 10..30u64 {
            c.access_ways(i * 64, false, 3);
        }
        assert!(c.probe(3 * 64), "reserved-way line was evicted");
    }

    #[test]
    fn second_tag_fills_lru_within_window_and_counts_no_hit() {
        // Regression for the single-pass rewrite: same victim policy as
        // the old find-then-lru version, same "no hit counted" contract.
        let mut c = Cache::new(256, 4, 64); // 1 set × 4 ways
        for i in 0..4u64 {
            c.access(i * 64, false);
        }
        c.access(0, false); // refresh line 0 → line 1 is LRU
        let out = c.access_second_tag(9 * 64, 3); // allocation window: 3 ways
        assert!(!out.hit);
        assert!(!c.probe(64), "LRU line inside the window evicted");
        assert!(c.probe(3 * 64), "reserved way untouched");
        assert_eq!(c.stats.read_misses, 5);
        let hits_before = c.stats.hits();
        assert!(c.access_second_tag(9 * 64, 3).hit);
        assert_eq!(c.stats.hits(), hits_before, "second tag match counts no hit");
    }

    #[test]
    fn prefetch_fill_tracks_usefulness() {
        let mut c = tiny();
        assert!(c.prefetch_fill(0x1000, 2).is_none());
        assert_eq!(c.stats.prefetch_fills, 1);
        assert!(c.access(0x1000, false).hit);
        assert_eq!(c.stats.prefetch_hits, 1);
        // Second fill of resident line is a no-op.
        c.prefetch_fill(0x1000, 2);
        assert_eq!(c.stats.prefetch_fills, 1);
    }

    #[test]
    fn invalidate_reports_dirtiness() {
        let mut c = tiny();
        c.access(0x40, true);
        assert!(c.invalidate(0x40));
        assert!(!c.probe(0x40));
        c.access(0x40, false);
        assert!(!c.invalidate(0x40));
    }

    #[test]
    fn capacity_bounds_property() {
        // Property: after any access sequence, valid lines ≤ capacity and
        // a just-accessed line is always resident.
        testutil::check_result(
            "cache capacity",
            128,
            |r: &mut SplitMix64| {
                (0..64).map(|_| (r.next_u64() % 0x4000) & !63).collect::<Vec<u64>>()
            },
            |addrs| {
                let mut c = tiny();
                for &a in addrs {
                    c.access(a, false);
                    if !c.probe(a) {
                        return Err(format!("just-accessed {a:#x} not resident"));
                    }
                }
                let valid = (0..0x4000u64)
                    .step_by(64)
                    .filter(|&a| c.probe(a))
                    .count();
                if valid > 4 {
                    return Err(format!("{valid} lines valid in a 4-line cache"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn hit_rate_computation() {
        let mut c = tiny();
        c.access(0, false);
        c.access(0, false);
        c.access(0, false);
        c.access(0, false);
        assert!((c.stats.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn reset_clears_everything() {
        let mut c = tiny();
        c.access(0x40, true);
        c.reset();
        assert!(!c.probe(0x40));
        assert_eq!(c.stats.accesses(), 0);
    }
}
