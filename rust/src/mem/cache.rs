//! Set-associative cache model: tags, LRU replacement, write-back +
//! write-allocate, optional way restriction (Casper reserves LLC ways for
//! concurrent CPU processes, §4.4).

/// Per-cache event counters (consumed by the energy model).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub read_hits: u64,
    pub read_misses: u64,
    pub write_hits: u64,
    pub write_misses: u64,
    pub evictions: u64,
    pub writebacks: u64,
    /// Fills injected by a prefetcher (tracked separately: they pollute).
    pub prefetch_fills: u64,
    /// Demand hits on prefetched lines (prefetch usefulness).
    pub prefetch_hits: u64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.read_hits + self.write_hits
    }
    pub fn misses(&self) -> u64 {
        self.read_misses + self.write_misses
    }
    pub fn accesses(&self) -> u64 {
        self.hits() + self.misses()
    }
    pub fn hit_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.hits() as f64 / self.accesses() as f64
        }
    }
    pub fn add(&mut self, o: &CacheStats) {
        self.read_hits += o.read_hits;
        self.read_misses += o.read_misses;
        self.write_hits += o.write_hits;
        self.write_misses += o.write_misses;
        self.evictions += o.evictions;
        self.writebacks += o.writebacks;
        self.prefetch_fills += o.prefetch_fills;
        self.prefetch_hits += o.prefetch_hits;
    }
}

/// Result of one access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccessOutcome {
    pub hit: bool,
    /// Dirty line evicted by the fill (its line address), if any.
    pub writeback: Option<u64>,
    /// The hit consumed a line a prefetcher installed (first demand touch
    /// of a prefetched line — it still cost a fill into this level).
    pub prefetch_hit: bool,
}

#[derive(Debug, Clone, Copy, Default)]
struct Way {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp (monotonic counter value at last touch).
    stamp: u64,
    /// Filled by prefetch and not yet demanded.
    prefetched: bool,
}

/// A tag-only set-associative cache with LRU replacement.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    ways: usize,
    line_shift: u32,
    data: Vec<Way>,
    clock: u64,
    pub stats: CacheStats,
}

impl Cache {
    /// `size_bytes` must be `sets * ways * line_bytes` with power-of-two
    /// sets and line size.
    pub fn new(size_bytes: usize, ways: usize, line_bytes: usize) -> Cache {
        assert!(line_bytes.is_power_of_two());
        assert!(size_bytes % (ways * line_bytes) == 0, "geometry mismatch");
        let sets = size_bytes / (ways * line_bytes);
        assert!(sets.is_power_of_two(), "set count must be a power of two");
        Cache {
            sets,
            ways,
            line_shift: line_bytes.trailing_zeros(),
            data: vec![Way::default(); sets * ways],
            clock: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn from_config(cfg: &crate::config::CacheConfig) -> Cache {
        Cache::new(cfg.size_bytes, cfg.ways, cfg.line_bytes)
    }

    #[inline]
    pub fn line_bytes(&self) -> usize {
        1 << self.line_shift
    }

    #[inline]
    pub fn line_of(&self, addr: u64) -> u64 {
        addr >> self.line_shift
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        (line as usize) & (self.sets - 1)
    }

    /// Demand access with allocate-on-miss over all ways.
    pub fn access(&mut self, addr: u64, write: bool) -> AccessOutcome {
        self.access_ways(addr, write, self.ways)
    }

    /// Demand access restricted to the first `way_limit` ways (Casper's
    /// LLC way reservation: stencil data may not evict the reserved ways).
    pub fn access_ways(&mut self, addr: u64, write: bool, way_limit: usize) -> AccessOutcome {
        debug_assert!(way_limit > 0 && way_limit <= self.ways);
        let line = self.line_of(addr);
        let set = self.set_of(line);
        self.clock += 1;
        let base = set * self.ways;

        // Single pass: hit check across ALL ways (a line resident in a
        // reserved way still hits; the restriction is only on allocation)
        // while simultaneously tracking the in-window LRU victim — the
        // miss path then needs no second scan (§Perf: this function is
        // ~30% of simulator time).
        let mut victim = 0usize;
        let mut victim_stamp = u64::MAX;
        let set_ways = &mut self.data[base..base + self.ways];
        for (w, e) in set_ways.iter_mut().enumerate() {
            if e.valid && e.tag == line {
                e.stamp = self.clock;
                let prefetch_hit = e.prefetched;
                if prefetch_hit {
                    e.prefetched = false;
                    self.stats.prefetch_hits += 1;
                }
                if write {
                    e.dirty = true;
                    self.stats.write_hits += 1;
                } else {
                    self.stats.read_hits += 1;
                }
                return AccessOutcome { hit: true, writeback: None, prefetch_hit };
            }
            if w < way_limit {
                let stamp = if e.valid { e.stamp } else { 0 };
                if stamp < victim_stamp {
                    victim_stamp = stamp;
                    victim = w;
                }
            }
        }

        // Miss: allocate (write-allocate policy) in the LRU way within the
        // allowed window.
        if write {
            self.stats.write_misses += 1;
        } else {
            self.stats.read_misses += 1;
        }
        let writeback = self.fill_way(base + victim, line, write, false);
        AccessOutcome { hit: false, writeback, prefetch_hit: false }
    }

    /// State-updating access that does NOT count a hit — used for the
    /// second line of a §4.1 merged unaligned access: the dual tag port
    /// matches both lines under ONE data-array access, so energy/stats
    /// see a single access, but a miss on either line is still a real
    /// miss (counted, fill, possible writeback).
    pub fn access_second_tag(&mut self, addr: u64, way_limit: usize) -> AccessOutcome {
        let line = self.line_of(addr);
        let base = self.set_of(line) * self.ways;
        // Resident? Touch LRU only.
        self.clock += 1;
        for w in 0..self.ways {
            let e = &mut self.data[base + w];
            if e.valid && e.tag == line {
                e.stamp = self.clock;
                let prefetch_hit = e.prefetched;
                e.prefetched = false;
                return AccessOutcome { hit: true, writeback: None, prefetch_hit };
            }
        }
        self.stats.read_misses += 1;
        let victim = self.lru_way(base, way_limit);
        let writeback = self.fill_way(base + victim, line, false, false);
        AccessOutcome { hit: false, writeback, prefetch_hit: false }
    }

    /// Fill a line without a demand access (prefetch). Never counted as a
    /// hit/miss; may evict. Returns the writeback, if any. No-op if the
    /// line is already resident.
    pub fn prefetch_fill(&mut self, addr: u64, way_limit: usize) -> Option<u64> {
        let line = self.line_of(addr);
        let set = self.set_of(line);
        let base = set * self.ways;
        for w in 0..self.ways {
            if self.data[base + w].valid && self.data[base + w].tag == line {
                return None;
            }
        }
        self.clock += 1;
        self.stats.prefetch_fills += 1;
        let victim = self.lru_way(base, way_limit);
        self.fill_way(base + victim, line, false, true)
    }

    /// Probe without state change: is the line resident?
    pub fn probe(&self, addr: u64) -> bool {
        let line = self.line_of(addr);
        let base = self.set_of(line) * self.ways;
        (0..self.ways).any(|w| {
            let e = &self.data[base + w];
            e.valid && e.tag == line
        })
    }

    /// Invalidate a line (coherence). Returns true if it was dirty.
    pub fn invalidate(&mut self, addr: u64) -> bool {
        let line = self.line_of(addr);
        let base = self.set_of(line) * self.ways;
        for w in 0..self.ways {
            let e = &mut self.data[base + w];
            if e.valid && e.tag == line {
                e.valid = false;
                let dirty = e.dirty;
                e.dirty = false;
                return dirty;
            }
        }
        false
    }

    /// Fraction of valid lines (occupancy), for reports.
    pub fn occupancy(&self) -> f64 {
        let valid = self.data.iter().filter(|e| e.valid).count();
        valid as f64 / self.data.len() as f64
    }

    /// Reset tags and stats (new run).
    pub fn reset(&mut self) {
        self.data.fill(Way::default());
        self.stats = CacheStats::default();
        self.clock = 0;
    }

    /// Reset statistics only, keeping the tag state (end of a warm-up
    /// phase: subsequent measurement sees a warm cache with clean
    /// counters).
    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    fn lru_way(&self, base: usize, way_limit: usize) -> usize {
        // Prefer an invalid way inside the window; else the LRU stamp.
        let mut victim = 0usize;
        let mut best = u64::MAX;
        for w in 0..way_limit {
            let e = &self.data[base + w];
            if !e.valid {
                return w;
            }
            if e.stamp < best {
                best = e.stamp;
                victim = w;
            }
        }
        victim
    }

    fn fill_way(&mut self, idx: usize, line: u64, dirty: bool, prefetched: bool) -> Option<u64> {
        let e = &mut self.data[idx];
        let mut writeback = None;
        if e.valid {
            self.stats.evictions += 1;
            if e.dirty {
                self.stats.writebacks += 1;
                writeback = Some(e.tag);
            }
        }
        e.tag = line;
        e.valid = true;
        e.dirty = dirty;
        e.stamp = self.clock;
        e.prefetched = prefetched;
        writeback
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;
    use crate::util::SplitMix64;

    fn tiny() -> Cache {
        // 2 sets × 2 ways × 64 B = 256 B.
        Cache::new(256, 2, 64)
    }

    #[test]
    fn miss_then_hit() {
        let mut c = tiny();
        assert!(!c.access(0x1000, false).hit);
        assert!(c.access(0x1000, false).hit);
        assert!(c.access(0x1038, false).hit, "same line");
        assert_eq!(c.stats.read_hits, 2);
        assert_eq!(c.stats.read_misses, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = tiny();
        // Set 0 lines: line numbers even (2 sets, line = addr>>6, set = line&1).
        c.access(0x000, false); // line 0 set 0
        c.access(0x100, false); // line 4 set 0
        c.access(0x000, false); // touch line 0 → line 4 is LRU
        c.access(0x200, false); // line 8 set 0 → evicts line 4
        assert!(c.probe(0x000));
        assert!(!c.probe(0x100));
        assert!(c.probe(0x200));
    }

    #[test]
    fn writeback_on_dirty_eviction() {
        let mut c = tiny();
        c.access(0x000, true); // dirty line 0
        c.access(0x100, false);
        let out = c.access(0x200, false); // evicts LRU = line 0 (dirty)
        assert_eq!(out.writeback, Some(0));
        assert_eq!(c.stats.writebacks, 1);
    }

    #[test]
    fn way_restriction_protects_reserved_way() {
        // 1 set × 4 ways.
        let mut c = Cache::new(256, 4, 64);
        // Fill all 4 ways (unrestricted).
        for i in 0..4u64 {
            c.access(i * 64, false);
        }
        // Touch way occupants so stamps are ordered 0..3; then restricted
        // allocation (3 ways) must never evict whatever sits in way 3.
        let before = c.probe(3 * 64);
        assert!(before);
        for i in 10..30u64 {
            c.access_ways(i * 64, false, 3);
        }
        assert!(c.probe(3 * 64), "reserved-way line was evicted");
    }

    #[test]
    fn prefetch_fill_tracks_usefulness() {
        let mut c = tiny();
        assert!(c.prefetch_fill(0x1000, 2).is_none());
        assert_eq!(c.stats.prefetch_fills, 1);
        assert!(c.access(0x1000, false).hit);
        assert_eq!(c.stats.prefetch_hits, 1);
        // Second fill of resident line is a no-op.
        c.prefetch_fill(0x1000, 2);
        assert_eq!(c.stats.prefetch_fills, 1);
    }

    #[test]
    fn invalidate_reports_dirtiness() {
        let mut c = tiny();
        c.access(0x40, true);
        assert!(c.invalidate(0x40));
        assert!(!c.probe(0x40));
        c.access(0x40, false);
        assert!(!c.invalidate(0x40));
    }

    #[test]
    fn capacity_bounds_property() {
        // Property: after any access sequence, valid lines ≤ capacity and
        // a just-accessed line is always resident.
        testutil::check_result(
            "cache capacity",
            128,
            |r: &mut SplitMix64| {
                (0..64).map(|_| (r.next_u64() % 0x4000) & !63).collect::<Vec<u64>>()
            },
            |addrs| {
                let mut c = tiny();
                for &a in addrs {
                    c.access(a, false);
                    if !c.probe(a) {
                        return Err(format!("just-accessed {a:#x} not resident"));
                    }
                }
                let valid = (0..0x4000u64)
                    .step_by(64)
                    .filter(|&a| c.probe(a))
                    .count();
                if valid > 4 {
                    return Err(format!("{valid} lines valid in a 4-line cache"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn hit_rate_computation() {
        let mut c = tiny();
        c.access(0, false);
        c.access(0, false);
        c.access(0, false);
        c.access(0, false);
        assert!((c.stats.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn reset_clears_everything() {
        let mut c = tiny();
        c.access(0x40, true);
        c.reset();
        assert!(!c.probe(0x40));
        assert_eq!(c.stats.accesses(), 0);
    }
}
