//! Stride prefetcher ("stride prefetchers at all levels of the cache",
//! Table 2).
//!
//! Classic reference-prediction-table design: track distinct access
//! streams, detect a stable line-level stride after two confirmations, and
//! issue `degree` prefetches ahead of the demand stream. The LLC-level
//! instance is what reproduces the paper's Blur-2D DRAM anomaly (§8.1):
//! with many concurrent streams, prefetched lines evict demand lines and
//! the LLC hit rate collapses.

use crate::config::PrefetchConfig;

/// A small fixed batch of prefetch targets (line addresses).
#[derive(Debug, Clone, Copy)]
pub struct Prefetches {
    lines: [u64; Self::CAP],
    n: usize,
}

impl Prefetches {
    pub const CAP: usize = 8;
    pub const NONE: Prefetches = Prefetches { lines: [0; Self::CAP], n: 0 };

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.lines[..self.n].iter().copied()
    }

    /// Collect to a Vec (test convenience).
    pub fn to_vec(&self) -> Vec<u64> {
        self.lines[..self.n].to_vec()
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct StreamEntry {
    valid: bool,
    /// Stream tag (we key streams by a caller-supplied id — core/SPU and
    /// array — mirroring PC-based stream separation in real prefetchers).
    key: u64,
    last_line: u64,
    stride: i64,
    confidence: u8,
}

/// A stride prefetcher with `streams` table entries.
#[derive(Debug, Clone)]
pub struct StridePrefetcher {
    cfg: PrefetchConfig,
    table: Vec<StreamEntry>,
    next_victim: usize,
    /// MRU hint: streams are bursty, so the same entry is usually hit
    /// repeatedly — check it before the linear scan (§Perf).
    mru: usize,
    pub issued: u64,
}

impl StridePrefetcher {
    pub fn new(cfg: &PrefetchConfig) -> StridePrefetcher {
        StridePrefetcher {
            cfg: *cfg,
            table: vec![StreamEntry::default(); cfg.streams.max(1)],
            next_victim: 0,
            mru: 0,
            issued: 0,
        }
    }

    /// Observe a demand access (line address) on stream `key`; returns the
    /// line addresses to prefetch (none until the stride is confirmed).
    /// Returns a fixed buffer + count to keep the hot path allocation-free
    /// (§Perf).
    pub fn observe(&mut self, key: u64, line: u64) -> Prefetches {
        if !self.cfg.enabled {
            return Prefetches::NONE;
        }
        // Find or allocate the stream entry (MRU hint first).
        let hint = &self.table[self.mru];
        let idx = if hint.valid && hint.key == key {
            self.mru
        } else {
            match self.table.iter().position(|e| e.valid && e.key == key) {
                Some(i) => {
                    self.mru = i;
                    i
                }
                None => {
                    let v = self.next_victim;
                    self.next_victim = (self.next_victim + 1) % self.table.len();
                    self.table[v] = StreamEntry {
                        valid: true,
                        key,
                        last_line: line,
                        stride: 0,
                        confidence: 0,
                    };
                    self.mru = v;
                    return Prefetches::NONE;
                }
            }
        };
        let e = &mut self.table[idx];
        let stride = line as i64 - e.last_line as i64;
        e.last_line = line;
        if stride == 0 {
            return Prefetches::NONE; // same line re-touch
        }
        if stride == e.stride {
            e.confidence = e.confidence.saturating_add(1);
        } else {
            e.stride = stride;
            e.confidence = 1;
            return Prefetches::NONE;
        }
        if e.confidence < 2 {
            return Prefetches::NONE;
        }
        let stride = e.stride;
        let mut out = Prefetches::NONE;
        for k in 1..=self.cfg.degree.min(Prefetches::CAP) as i64 {
            let target = line as i64 + stride * k;
            if target >= 0 {
                out.lines[out.n] = target as u64;
                out.n += 1;
            }
        }
        self.issued += out.n as u64;
        out
    }

    pub fn reset(&mut self) {
        self.table.fill(StreamEntry::default());
        self.next_victim = 0;
        self.mru = 0;
        self.issued = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pf() -> StridePrefetcher {
        StridePrefetcher::new(&PrefetchConfig { enabled: true, streams: 4, degree: 2 })
    }

    #[test]
    fn detects_unit_stride_after_confirmation() {
        let mut p = pf();
        assert!(p.observe(1, 100).is_empty()); // allocate
        assert!(p.observe(1, 101).is_empty()); // stride learned, conf 1
        let out = p.observe(1, 102).to_vec(); // confirmed
        assert_eq!(out, vec![103, 104]);
    }

    #[test]
    fn detects_negative_stride() {
        let mut p = pf();
        p.observe(1, 100);
        p.observe(1, 98);
        let out = p.observe(1, 96).to_vec();
        assert_eq!(out, vec![94, 92]);
    }

    #[test]
    fn stride_change_resets_confidence() {
        let mut p = pf();
        p.observe(1, 100);
        p.observe(1, 101);
        p.observe(1, 102);
        assert!(p.observe(1, 110).is_empty()); // stride breaks → conf 1
        assert_eq!(p.observe(1, 118).to_vec(), vec![126, 134]); // stride 8 confirmed
        assert_eq!(p.observe(1, 126).to_vec(), vec![134, 142]);
    }

    #[test]
    fn independent_streams() {
        let mut p = pf();
        p.observe(1, 100);
        p.observe(2, 500);
        p.observe(1, 101);
        p.observe(2, 502);
        assert_eq!(p.observe(1, 102).to_vec(), vec![103, 104]);
        assert_eq!(p.observe(2, 504).to_vec(), vec![506, 508]);
    }

    #[test]
    fn table_capacity_evicts_round_robin() {
        let mut p = pf(); // 4 entries
        for key in 0..5u64 {
            p.observe(key, key * 1000);
        }
        // key 0 was evicted; re-observing it reallocates (no prefetch).
        assert!(p.observe(0, 1).is_empty());
    }

    #[test]
    fn disabled_never_prefetches() {
        let mut p = StridePrefetcher::new(&PrefetchConfig {
            enabled: false,
            streams: 4,
            degree: 2,
        });
        for i in 0..10 {
            assert!(p.observe(1, 100 + i).is_empty());
        }
    }

    #[test]
    fn same_line_retouch_ignored() {
        let mut p = pf();
        p.observe(1, 100);
        p.observe(1, 101);
        p.observe(1, 102);
        assert!(p.observe(1, 102).is_empty());
        // Stream continues afterwards.
        assert_eq!(p.observe(1, 103).to_vec(), vec![104, 105]);
    }
}
