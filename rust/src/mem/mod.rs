//! The memory subsystem: set-associative caches, the sliced LLC, DRAM,
//! stride prefetchers, and Casper's unaligned-load support (§4.1).
//!
//! The simulator is *decoupled*: functional data lives in the grids
//! ([`crate::stencil::Grid`]); these models track tags, occupancy, timing,
//! and event counts. That is the standard trace-driven style and keeps the
//! hot path fast while the event counts feed the energy model unchanged.

pub mod cache;
pub mod dram;
pub mod hierarchy;
pub mod prefetch;
pub mod ratelimit;
pub mod unaligned;

pub use cache::{AccessOutcome, Cache, CacheStats};
pub use dram::DramModel;
pub use hierarchy::{CpuHierarchy, MemEvents};
pub use prefetch::StridePrefetcher;
pub use unaligned::UnalignedReq;
