//! Credit-based rate limiter for single-ported resources (LLC slice
//! ports, DRAM channel buses).
//!
//! The simulator processes agents in rounds, so claims on a shared port
//! arrive slightly out of timestamp order. A naive monotonic
//! `next_free` scheduler then loses real capacity: a claim stamped in the
//! future pushes `next_free` past idle cycles that an earlier-stamped,
//! later-processed claim could have used. This limiter keeps a bounded
//! credit of recently-skipped idle cycles so reordered claims can backfill
//! them — long-run throughput stays ≤ 1 grant per `cost` cycles, while
//! bounded reordering no longer fabricates contention.

/// A single-server queue with service `cost` cycles per grant and an
/// idle-backfill window of `credit_cap` grants.
#[derive(Debug, Clone)]
pub struct RateLimiter {
    /// Virtual time: next cycle the server is free for in-order arrivals.
    vt: u64,
    /// Backfill credit, in grants.
    credit: u64,
    credit_cap: u64,
    cost: u64,
    /// Total grant count and cumulative queueing delay (diagnostics).
    pub grants: u64,
    pub wait_cycles: u64,
}

impl RateLimiter {
    pub fn new(cost: u64, credit_cap: u64) -> RateLimiter {
        assert!(cost > 0);
        RateLimiter { vt: 0, credit: 0, credit_cap, cost, grants: 0, wait_cycles: 0 }
    }

    /// Claim the resource for a request arriving at `arrive`; returns the
    /// cycle service *starts*.
    pub fn claim(&mut self, arrive: u64) -> u64 {
        self.grants += 1;
        if arrive >= self.vt {
            // Idle gap: bank it (bounded) and serve immediately.
            let idle_grants = (arrive - self.vt) / self.cost;
            self.credit = (self.credit + idle_grants).min(self.credit_cap);
            self.vt = arrive + self.cost;
            arrive
        } else if self.credit > 0 {
            // Late-processed claim backfills a previously-skipped slot.
            self.credit -= 1;
            arrive
        } else {
            let start = self.vt;
            self.wait_cycles += start - arrive;
            self.vt += self.cost;
            start
        }
    }

    pub fn reset(&mut self) {
        self.vt = 0;
        self.credit = 0;
        self.grants = 0;
        self.wait_cycles = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_order_claims_serialize() {
        // With no backfill credit the limiter is a plain 1/cycle port.
        let mut p = RateLimiter::new(1, 0);
        assert_eq!(p.claim(10), 10);
        assert_eq!(p.claim(10), 11);
        assert_eq!(p.claim(10), 12);
        assert!(p.wait_cycles > 0);
    }

    #[test]
    fn initial_idle_window_allows_bounded_burst() {
        // With credit, a burst after idle time backfills up to the cap —
        // the deliberate smoothing that tolerates out-of-order claims.
        let mut p = RateLimiter::new(1, 16);
        assert_eq!(p.claim(10), 10);
        assert_eq!(p.claim(10), 10); // backfills banked idle cycles
        for _ in 0..9 {
            p.claim(10);
        }
        // Credit (10 banked) exhausted: now it serializes.
        assert!(p.claim(10) > 10);
    }

    #[test]
    fn idle_gap_grants_credit_for_stragglers() {
        let mut p = RateLimiter::new(1, 16);
        assert_eq!(p.claim(0), 0);
        // A future claim opens a 99-cycle idle window...
        assert_eq!(p.claim(100), 100);
        // ...which a late-processed claim stamped at 50 backfills.
        assert_eq!(p.claim(50), 50);
    }

    #[test]
    fn credit_is_bounded() {
        let mut p = RateLimiter::new(1, 4);
        p.claim(0);
        p.claim(1000); // idle gap of 999 → credit capped at 4
        for i in 0..4 {
            assert_eq!(p.claim(10 + i), 10 + i, "backfill {i}");
        }
        // Credit exhausted: the next past-stamped claim queues at vt.
        assert!(p.claim(20) >= 1001);
    }

    #[test]
    fn long_run_rate_is_bounded() {
        // 10k claims all stamped at 0 → last service start ≥ 10k-ish.
        let mut p = RateLimiter::new(1, 64);
        let mut last = 0;
        for _ in 0..10_000 {
            last = last.max(p.claim(0));
        }
        assert!(last >= 10_000 - 65, "{last}");
    }

    #[test]
    fn cost_scales_service() {
        let mut p = RateLimiter::new(7, 4);
        assert_eq!(p.claim(0), 0);
        assert_eq!(p.claim(0), 7);
        assert_eq!(p.claim(0), 14);
    }
}
