//! PJRT runtime: load AOT-compiled stencil artifacts (HLO text produced by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client.
//!
//! This is the numeric cross-validation path: the JAX/Pallas-lowered
//! computation runs *from Rust* (Python never on the request path) and its
//! output is compared against the simulator's functional result and the
//! golden reference. A production deployment would use exactly this
//! loader with TPU-compiled artifacts.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::stencil::{Grid, StencilKind};

/// One entry of `artifacts/manifest.txt`:
/// `name kernel nx ny nz steps file`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    pub kernel: StencilKind,
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    pub steps: usize,
    pub file: PathBuf,
}

impl ArtifactEntry {
    pub fn points(&self) -> usize {
        self.nx * self.ny * self.nz
    }
}

/// Parse `manifest.txt`. Paths are resolved relative to the manifest dir.
pub fn parse_manifest(path: &Path) -> Result<Vec<ArtifactEntry>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading manifest {}", path.display()))?;
    let dir = path.parent().unwrap_or(Path::new("."));
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 7 {
            bail!("manifest line {}: expected 7 fields, got {}", lineno + 1, f.len());
        }
        let kernel = StencilKind::parse(f[1])
            .with_context(|| format!("manifest line {}: unknown kernel '{}'", lineno + 1, f[1]))?;
        out.push(ArtifactEntry {
            name: f[0].to_string(),
            kernel,
            nx: f[2].parse().context("nx")?,
            ny: f[3].parse().context("ny")?,
            nz: f[4].parse().context("nz")?,
            steps: f[5].parse().context("steps")?,
            file: dir.join(f[6]),
        });
    }
    Ok(out)
}

/// The PJRT-backed stencil runtime: a CPU client plus a cache of compiled
/// executables keyed by artifact name.
pub struct StencilRuntime {
    client: xla::PjRtClient,
    entries: HashMap<String, ArtifactEntry>,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl StencilRuntime {
    /// Load the manifest in `artifacts_dir` and create the PJRT client.
    pub fn new(artifacts_dir: &Path) -> Result<StencilRuntime> {
        let manifest = artifacts_dir.join("manifest.txt");
        let entries = parse_manifest(&manifest)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(StencilRuntime {
            client,
            entries: entries.into_iter().map(|e| (e.name.clone(), e)).collect(),
            compiled: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn entries(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.entries.values()
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    /// Find the artifact for a kernel with the given step count and the
    /// smallest point count (the validation-sized one).
    pub fn smallest_for(&self, kernel: StencilKind, steps: usize) -> Option<&ArtifactEntry> {
        self.entries
            .values()
            .filter(|e| e.kernel == kernel && e.steps == steps)
            .min_by_key(|e| e.points())
    }

    /// Compile (and cache) an artifact.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .entries
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))?;
        let path_str = entry
            .file
            .to_str()
            .context("artifact path not UTF-8")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path_str)
            .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on a grid. The grid's shape must match the
    /// artifact's; returns the stepped grid.
    pub fn execute(&mut self, name: &str, input: &Grid) -> Result<Grid> {
        self.compile(name)?;
        let entry = &self.entries[name];
        if (input.nx, input.ny, input.nz) != (entry.nx, entry.ny, entry.nz) {
            bail!(
                "grid {}x{}x{} does not match artifact '{name}' ({}x{}x{})",
                input.nx, input.ny, input.nz, entry.nx, entry.ny, entry.nz
            );
        }
        // Natural-shape literal: (nx,), (ny,nx) or (nz,ny,nx) — row-major
        // with x fastest matches the Grid layout exactly.
        let dims: Vec<i64> = if entry.nz > 1 {
            vec![entry.nz as i64, entry.ny as i64, entry.nx as i64]
        } else if entry.ny > 1 {
            vec![entry.ny as i64, entry.nx as i64]
        } else {
            vec![entry.nx as i64]
        };
        let lit = xla::Literal::vec1(&input.data).reshape(&dims)?;
        let exe = &self.compiled[name];
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f64>()?;
        if values.len() != input.len() {
            bail!("artifact '{name}' returned {} values, expected {}", values.len(), input.len());
        }
        let mut grid = Grid::zeros(input.nx, input.ny, input.nz);
        grid.data.copy_from_slice(&values);
        Ok(grid)
    }
}

/// Default artifacts directory: `$CASPER_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("CASPER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True when the artifacts have been built (used by tests to skip
/// gracefully before `make artifacts`).
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("casper_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.txt");
        std::fs::write(
            &p,
            "jacobi1d_tiny jacobi1d 256 1 1 1 jacobi1d_tiny.hlo.txt\n\
             heat3d_tiny heat3d 16 12 8 1 heat3d_tiny.hlo.txt\n",
        )
        .unwrap();
        let entries = parse_manifest(&p).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].kernel, StencilKind::Jacobi1D);
        assert_eq!(entries[1].nz, 8);
        assert_eq!(entries[1].points(), 16 * 12 * 8);
        assert!(entries[0].file.ends_with("jacobi1d_tiny.hlo.txt"));
    }

    #[test]
    fn manifest_rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("casper_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.txt");
        std::fs::write(&p, "too few fields\n").unwrap();
        assert!(parse_manifest(&p).is_err());
        std::fs::write(&p, "x unknownkernel 1 1 1 1 f\n").unwrap();
        assert!(parse_manifest(&p).is_err());
    }
}
